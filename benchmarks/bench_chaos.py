"""Chaos benchmark — graceful degradation under injected faults (PR 7).

Replays the same seeded Poisson trace through the paged engine twice —
clean, then under a deterministic :class:`repro.robustness.FaultPlan` —
and *asserts* the degradation contract rather than just recording numbers:

  * ``Engine.run`` returns under injection (never raises away completed
    work);
  * every request ends in exactly one terminal status
    (``completed | timeout | rejected | failed``);
  * fault-untouched requests produce token-for-token identical output vs
    the clean run (failure isolation, checked under greedy decoding with
    shared params);
  * the page-pool audit (``free + held == total_pages - 1``, no page in
    two places) is clean after every recovery action and at exit.

Two scenarios:

  * **recover** — page-allocation failures, an injected step-compute
    failure, and a NaN-logits burst: everything the engine can absorb
    by stall/evict, retry/requeue and slot quarantine.
  * **degrade** — overload (admission budget + tight per-request
    deadlines) plus a mid-run preemption: the engine must *shed*
    structuredly (``rejected``/``timeout`` records, partial tokens kept)
    and drain in-flight work.

Results merge into ``BENCH_serve.json`` under ``"chaos"``; also runnable
as ``python -m benchmarks.bench_serve --chaos`` or
``python -m benchmarks.run chaos``.
"""
from __future__ import annotations

import json
import os

import benchmarks.common  # noqa: F401  (sets REPRO_CPU_EXEC before jax use)

from repro.configs import get_config, smoke_variant
from repro.robustness import FaultPlan

_GEOM = dict(slots=4, page_size=8, max_pages=6, total_pages=14, chunk=16,
             burst=4)

# Documented goodput floors, asserted per scenario: the fraction of the
# clean run's goodput that must survive the injected fault mix.  `recover`
# absorbs its faults with bounded rework (retry/evict/quarantine), so most
# throughput survives; `degrade` sheds most of the offered load *by design*
# (overload + preemption) — its contract is structured shedding, so the
# floor is only "some work still completes".  A goodput_retained number is
# meaningless without the fault mix that produced it, so both are reported
# together.
SCENARIO_CONTRACTS = {
    "recover": {
        "floor": 0.15,
        "fault_mix": "page_alloc(prob=0.25,max=6) + step@1 + nan_logits@2",
    },
    "degrade": {
        "floor": 0.02,
        "fault_mix": "preempt@8 + admission_budget=4 + deadline=30s "
                     "+ rate 200req/s",
    },
}


def _cfg():
    return smoke_variant(get_config("llama3-8b")).with_(
        head_dim=64, kv_cache_dtype="int8")


def chaos_scenarios(backend: str = "ref", seed: int = 11) -> dict:
    """Run both scenarios; returns {name: chaos_replay record}.  Raises
    AssertionError if any part of the degradation contract is violated."""
    from benchmarks.bench_serve import chaos_replay, make_trace

    cfg = _cfg()
    out = {}

    # recover: faults the engine absorbs without losing untouched requests.
    # page_alloc failures force stall/evict, the step fault exercises
    # retry-requeue, the poisoned page trips the in-graph non-finite guard
    trace = make_trace(cfg, 10, rate_hz=50.0, plen=(8, 16), gen=(4, 20),
                       seed=seed, gen_skew=2.0)
    faults = FaultPlan(seed, {
        "engine.page_alloc": {"prob": 0.25, "max_fires": 6},
        "engine.step": {"at": (1,)},
        "engine.nan_logits": {"at": (2,)},
    })
    rec = chaos_replay(cfg, trace, backend=backend, faults=faults,
                       seed=seed, **_GEOM)
    assert rec["identical_completed"], (
        "fault isolation violated — completed requests diverged from the "
        f"clean run: rids {rec['mismatched_rids']}")
    assert rec["page_audit"]["ok"], rec["page_audit"]
    assert not rec["audit_failures"], rec["audit_failures"]
    assert rec["chaos"]["statuses"].get("completed", 0) >= len(trace) - 3, (
        "recover scenario lost more requests than the injected faults "
        f"can account for: {rec['chaos']['statuses']}")
    out["recover"] = rec

    _check_floor("recover", rec)

    # degrade: overload + deadlines + preemption — the contract is
    # *structured* shedding, not completion
    trace = make_trace(cfg, 12, rate_hz=200.0, plen=(8, 16), gen=(4, 16),
                       seed=seed + 1, gen_skew=2.0)
    for r in trace:
        r.deadline_s = 30.0
    faults = FaultPlan(seed + 1, {"engine.preempt": {"at": (8,)}})
    rec = chaos_replay(cfg, trace, backend=backend, faults=faults,
                       seed=seed, admission_budget=4, **_GEOM)
    assert rec["page_audit"]["ok"], rec["page_audit"]
    assert not rec["audit_failures"], rec["audit_failures"]
    assert rec["chaos"]["preempted"], (
        "preemption fault never fired — drain path untested: "
        f"{rec['faults']}")
    assert rec["identical_completed"], rec["mismatched_rids"]
    out["degrade"] = rec

    _check_floor("degrade", rec)
    return out


def _check_floor(name: str, rec: dict):
    """Stamp the scenario record with its contract (fault mix + floor) and
    assert the documented goodput floor — a retained-goodput number is only
    meaningful next to the fault mix that produced it."""
    contract = SCENARIO_CONTRACTS[name]
    rec["fault_mix"] = contract["fault_mix"]
    rec["goodput_floor"] = contract["floor"]
    assert rec["goodput_retained"] >= contract["floor"], (
        f"{name} scenario under fault mix [{contract['fault_mix']}] "
        f"retained {rec['goodput_retained']:.3f} of clean goodput — "
        f"below the documented floor {contract['floor']}")


def run(report):
    """benchmarks.run entry point: seeded chaos scenarios on the smoke
    config + merge into BENCH_serve.json (section ``"chaos"``)."""
    scenarios = chaos_scenarios(backend="ref")
    for name, sc in scenarios.items():
        ch = sc["chaos"]
        report(f"chaos/{name}/goodput_retained", sc["goodput_retained"],
               f"fault_mix=[{sc['fault_mix']}] "
               f"floor={sc['goodput_floor']} "
               f"statuses={ch['statuses']} evictions={ch['evictions']} "
               f"retries={ch['retries']} quarantined={ch['quarantined']} "
               f"shed={ch['shed']} identical={sc['identical_completed']} "
               f"audit_ok={sc['page_audit']['ok']}")

    path = "BENCH_serve.json"
    rec = {}
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
    rec["chaos"] = scenarios
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    report("chaos/json", 0.0, f"merged chaos section into {path}")


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="ref",
                    choices=["pallas", "interpret", "ref", "dense"])
    ap.add_argument("--seed", type=int, default=11)
    args = ap.parse_args(argv)
    for name, sc in chaos_scenarios(args.backend, args.seed).items():
        print(f"[bench_chaos] {name}: statuses={sc['chaos']['statuses']} "
              f"identical={sc['identical_completed']} "
              f"audit_ok={sc['page_audit']['ok']} "
              f"goodput_retained={sc['goodput_retained']} "
              f"(floor {sc['goodput_floor']}, "
              f"fault mix [{sc['fault_mix']}])")


if __name__ == "__main__":
    main()
