import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
# I5: seq_parallel + remat dots + micro 4096 tokens (temp guard for dots)
rec = run_cell("llama3-8b", "train_4k",
               plan_tweaks=dict(seq_parallel=True, target_micro_tokens=4096),
               cfg_mutate=lambda c: c.with_(remat_policy="dots"),
               verbose=True)
json.dump(rec, open("/root/repo/perf/llama8b_I5.json", "w"), indent=1)
