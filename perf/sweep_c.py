import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell

# missing cells, single-pod (roofline) first, smallest archs first
CELLS_16 = []
for a in ["musicgen-medium", "qwen3-4b", "xlstm-1.3b", "minitron-4b",
          "qwen3-8b", "minicpm3-4b", "phi3.5-moe-42b-a6.6b"]:
    for s in ["decode_32k", "prefill_32k", "train_4k"]:
        CELLS_16.append((a, s, False))
CELLS_16.append(("xlstm-1.3b", "long_500k", True))
CELLS_MP = [(a, s, True) for (a, s, _) in CELLS_16]

SKIP = {("minicpm3-4b", "train_4k", False)}
records = []
for a, s, mp in CELLS_16 + CELLS_MP:
    if (a, s, mp) in SKIP:
        continue
    try:
        records.append(run_cell(a, s, multi_pod=mp, probes=not mp))
    except Exception as e:
        records.append({"arch": a, "shape": s,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": f"FAIL: {e}"})
        print("[FAIL]", a, s, mp, repr(e)[:200], flush=True)
    json.dump(records, open("/root/repo/dryrun_results_c.json", "w"), indent=1)
