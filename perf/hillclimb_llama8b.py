"""Hillclimb iterations for llama3-8b x train_4k (LoRDS-PEFT, 16x16 mesh)."""
import os, json, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

from repro.launch.dryrun import run_cell

ITERS = {
    # paper-faithful baseline (recorded again for the log's 'before')
    "baseline": dict(),
    # I1: remat dots policy — hypothesis: flops -25% (no fwd recompute),
    # collectives -25% (recomputed fwd collectives vanish); temp +~2GB
    "I1_remat_dots": dict(cfg=dict(remat_policy="dots")),
    # I2: bf16 elementwise (norm/rope application) — hypothesis: the f32
    # (b,s,d) elementwise chains halve -> memory term -15-25%
    "I2_bf16_elemwise": dict(env={"REPRO_BF16_ELEMWISE": "1"}),
    # I3: sequence-parallel residuals — hypothesis: carries/norm traffic /16,
    # TP all-reduce -> RS+AG halves those collective bytes
    "I3_seq_parallel": dict(plan=dict(seq_parallel=True)),
    # I4: bf16 S=B*A product — hypothesis: dequant scale traffic /2
    "I4_ba_bf16": dict(quant=dict(ba_compute_dtype="bf16")),
    # I5: combine the winners (filled after measuring)
}

def mutate(cfg_kw, quant_kw):
    import jax.numpy as jnp
    def fn(cfg):
        if quant_kw:
            qk = dict(quant_kw)
            if qk.get("ba_compute_dtype") == "bf16":
                qk["ba_compute_dtype"] = jnp.bfloat16
            cfg = cfg.with_(quant=cfg.quant.with_(**qk))
        if cfg_kw:
            cfg = cfg.with_(**cfg_kw)
        return cfg
    return fn

def main():
    which = sys.argv[1:] or list(ITERS)
    out = {}
    for name in which:
        spec = ITERS[name]
        envs = spec.get("env", {})
        old = {k: os.environ.get(k) for k in envs}
        os.environ.update(envs)
        try:
            rec = run_cell("llama3-8b", "train_4k",
                           plan_tweaks=spec.get("plan"),
                           cfg_mutate=mutate(spec.get("cfg"), spec.get("quant")),
                           verbose=False)
            rl = rec["roofline"]
            out[name] = dict(t_c=rl["t_compute_s"], t_m=rl["t_memory_s"],
                             t_coll=rl["t_collective_s"], bound=rl["bottleneck"],
                             frac=rl["model_fraction_of_roofline"],
                             ratio=rl["model_flops_ratio"],
                             temp_gb=rec["memory"].get("temp_size_in_bytes",0)/1e9)
            print(name, json.dumps(out[name]), flush=True)
        finally:
            for k, v in old.items():
                if v is None: os.environ.pop(k, None)
                else: os.environ[k] = v
    json.dump(out, open("/root/repo/perf/llama8b_iters.json","w"), indent=1)

main()
