"""Merge all dry-run logs -> markdown tables -> EXPERIMENTS.md placeholders."""
import json, subprocess, sys
sys.path.insert(0, "/root/repo/perf")
from log_to_records import parse

LOGS = ["/tmp/dryrun_sweep.log", "/tmp/sweep_b.log", "/tmp/sweep_c.log",
        "/tmp/dry_kimi.log", "/tmp/dry_405.log", "/tmp/dry_jamba.log",
        "/tmp/dry_xlstm.log", "/tmp/hc_llama.log"]
recs = []
for p in LOGS:
    try:
        recs.extend(parse(p))
    except OSError:
        pass
seen = {}
for r in recs:
    seen[(r["arch"], r["shape"], r["mesh"])] = r
records = list(seen.values())
json.dump(records, open("/root/repo/dryrun_merged.json", "w"), indent=1)

ARCHS = ["minicpm3-4b","minitron-4b","llama3-405b","granite-20b",
         "phi3.5-moe-42b-a6.6b","kimi-k2-1t-a32b","internvl2-1b","xlstm-1.3b",
         "musicgen-medium","jamba-1.5-large-398b","llama3-8b","qwen3-8b","qwen3-4b"]
SHAPES = ["train_4k","prefill_32k","decode_32k","long_500k"]
LONG = {"xlstm-1.3b","jamba-1.5-large-398b"}

def fmt_s(x):
    x = max(x, 0.0)  # probe extrapolation can go (slightly) negative
    if x == 0: return "~0"
    if x < 1e-3: return f"{x*1e6:.0f}µs"
    if x < 1: return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"

def table(mesh):
    rows = ["| arch | shape | mem/dev (arg+temp GB) | fits | t_compute | t_memory | t_collective | bound | frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    n_ok = n_missing = 0
    for a in ARCHS:
        for s in SHAPES:
            if s == "long_500k" and a not in LONG:
                continue
            r = seen.get((a, s, mesh))
            if r is None:
                rows.append(f"| {a} | {s} | — | — | (not reached in sweep window) | | | | |")
                n_missing += 1
                continue
            n_ok += 1
            rl = r["roofline"]; mem = r["memory"]
            tot = (mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]) / 1e9
            fits = "✓" if tot < 16 else "✗"
            rows.append(
                f"| {a} | {s} | {mem['argument_size_in_bytes']/1e9:.2f}+{mem['temp_size_in_bytes']/1e9:.2f} | {fits} | "
                f"{fmt_s(rl['t_compute_s'])} | {fmt_s(rl['t_memory_s'])} | {fmt_s(rl['t_collective_s'])} | "
                f"{rl['bottleneck']} | {rl['model_fraction_of_roofline']:.3f} |")
    rows.append(f"\n({n_ok} cells compiled ok on this mesh; {n_missing} not reached)")
    return "\n".join(rows)

def table_mp():
    rows = ["| arch | shape | mem/dev (arg+temp GB) | fits <16GB | compiled+sharded |",
            "|---|---|---|---|---|"]
    n_ok = 0
    for a in ARCHS:
        for s in SHAPES:
            if s == "long_500k" and a not in LONG:
                continue
            r = seen.get((a, s, "2x16x16"))
            if r is None:
                rows.append(f"| {a} | {s} | — | — | (not reached) |")
                continue
            n_ok += 1
            mem = r["memory"]
            tot = (mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]) / 1e9
            fits = "✓" if tot < 16 else "✗"
            rows.append(
                f"| {a} | {s} | {mem['argument_size_in_bytes']/1e9:.2f}+{mem['temp_size_in_bytes']/1e9:.2f} | {fits} | ✓ |")
    rows.append(f"\n({n_ok} cells; the multi-pod pass proves the 'pod' axis shards — "
                "roofline terms are single-pod only per the methodology, since "
                "multi-pod cells compile without unrolled probes)")
    return "\n".join(rows)

md = open("/root/repo/EXPERIMENTS.md").read()
md = md.replace("<!-- DRYRUN_TABLE_16 -->", table("16x16"))
md = md.replace("<!-- DRYRUN_TABLE_512 -->", table_mp())
open("/root/repo/EXPERIMENTS.md", "w").write(md)
print("tables written;", len(records), "records total")
