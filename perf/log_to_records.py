"""Parse dry-run [ok] log lines into table records (fallback when a sweep is
interrupted before its JSON dump)."""
import json, re, sys

PAT = re.compile(
    r"\[ok\] (\S+)\s+(\S+)\s+mesh=(\S+)\s+args=\s*([\d.]+)GB temp=\s*([\d.]+)GB "
    r"t_c=([\d.e+-]+)s t_m=([\d.e+-]+)s t_coll=([\d.e+-]+)s bound=(\S+)\s+"
    r"frac=([\d.]+)")

def parse(path):
    out = []
    for line in open(path):
        m = PAT.search(line)
        if not m:
            continue
        a, sh, mesh, arg, tmp, tc, tm, tl, bound, frac = m.groups()
        out.append(dict(arch=a, shape=sh, mesh=mesh, status="ok",
                        memory={"argument_size_in_bytes": float(arg)*1e9,
                                "temp_size_in_bytes": float(tmp)*1e9},
                        kind={"train_4k":"train","prefill_32k":"prefill",
                              "decode_32k":"decode","long_500k":"decode"}[sh],
                        roofline={"t_compute_s": float(tc),
                                  "t_memory_s": float(tm),
                                  "t_collective_s": float(tl),
                                  "bottleneck": bound,
                                  "model_flops_ratio": 0.0,
                                  "model_fraction_of_roofline": float(frac)}))
    return out

if __name__ == "__main__":
    recs = []
    for p in sys.argv[1:]:
        recs.extend(parse(p))
    # dedupe by (arch, shape, mesh), last wins
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    json.dump(list(seen.values()), open("/root/repo/dryrun_merged.json", "w"),
              indent=1)
    print(f"{len(seen)} unique records")
