import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell, LONG_CTX_ARCHS

ARCHS = ["llama3-8b", "minicpm3-4b", "minitron-4b", "musicgen-medium",
         "phi3.5-moe-42b-a6.6b", "qwen3-4b", "qwen3-8b", "xlstm-1.3b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
records = []
for arch in ARCHS:
    for shape in SHAPES:
        if shape == "long_500k" and arch not in LONG_CTX_ARCHS:
            continue
        for mp in (False, True):
            try:
                records.append(run_cell(arch, shape, multi_pod=mp, probes=not mp))
            except Exception as e:
                records.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if mp else "16x16",
                                "status": f"FAIL: {e}"})
                print("[FAIL]", arch, shape, mp, e, flush=True)
            json.dump(records, open("/root/repo/dryrun_results_b.json", "w"), indent=1)
