"""llama3-405b x train_4k: L1 = sequence parallel (fits + memory term)."""
import os, json, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell

which = sys.argv[1] if len(sys.argv) > 1 else "L1"
if which == "L1":
    rec = run_cell("llama3-405b", "train_4k",
                   plan_tweaks=dict(seq_parallel=True), verbose=True)
elif which == "L2":  # L1 + remat dots + smaller micro
    rec = run_cell("llama3-405b", "train_4k",
                   plan_tweaks=dict(seq_parallel=True, target_micro_tokens=4096),
                   cfg_mutate=lambda c: c.with_(remat_policy="dots"),
                   verbose=True)
json.dump(rec, open(f"/root/repo/perf/l405_{which}.json", "w"), indent=1)
