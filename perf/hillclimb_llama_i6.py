import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
# I6: seq_parallel + remat dots at full 8192-token microbatches (SP shrinks
# the dot-output checkpoints 16x, so dots policy should now fit)
rec = run_cell("llama3-8b", "train_4k",
               plan_tweaks=dict(seq_parallel=True),
               cfg_mutate=lambda c: c.with_(remat_policy="dots"),
               verbose=True)
json.dump(rec, open("/root/repo/perf/llama8b_I6.json", "w"), indent=1)
