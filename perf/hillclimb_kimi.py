"""Kimi-k2 x train_4k hillclimb: K1 = shard_map EP dispatch (padded 512)."""
import os, json, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
import dataclasses

def k1(cfg):
    mo = dataclasses.replace(cfg.moe, dispatch="shard_map", pad_experts_to=512)
    return cfg.with_(moe=mo)

which = sys.argv[1] if len(sys.argv) > 1 else "K1"
out = {}
if which == "K1":
    rec = run_cell("kimi-k2-1t-a32b", "train_4k", cfg_mutate=k1, verbose=True)
elif which == "K2":  # K1 + bf16 elementwise + remat dots
    os.environ["REPRO_BF16_ELEMWISE"] = "1"
    rec = run_cell("kimi-k2-1t-a32b", "train_4k",
                   cfg_mutate=lambda c: k1(c).with_(remat_policy="dots"),
                   verbose=True)
json.dump(rec, open(f"/root/repo/perf/kimi_{which}.json", "w"), indent=1)
